"""RL environment over kernel programs (live + tree-structured offline).

Reward shaping follows the paper's three tiers, easy -> hard:
  (1) compiles        — failures penalised, penalty magnitude < tier-2/3
                        gains so exploration escapes the all-invalid zone;
  (2) runs correctly  — small positive baseline for any valid rewrite;
  (3) runs faster     — dominant reward, proportional to the speedup
                        delta over the previous step's kernel.
Positive rewards are scaled by a step-proportional decay (paper: "step-
proportional reward decay mechanism to mitigate degenerate looping"), so
re-applying no-op optimizations late in an episode earns ~nothing.

``OfflineTree`` caches (state, action) -> (child, status, cost): policy
training replays materialized transitions only (the paper's offline tree
built from pre-collected trajectories — no live Micro Coding latency in
the PPO loop).

**Reward sources.**  The paper's reward is *measured* performance; the
seed trained on the analytic roofline only.  ``RewardSource`` is the
pricing seam the environments (and ``OfflineTree`` node costs) draw
their speedup rewards from:

  analytic    — the roofline cost model (the seed's behavior);
  calibrated  — roofline scaled by per-(target, bottleneck) factors fit
                from a measurement DB (``measure/calibrate.py``);
  measured    — wall-clock times REPLAYED from a persistent ``MeasureDB``
                (``measure/db.py``), falling back to the calibrated/
                analytic model for programs the DB never timed.  Replay
                only: training stays hermetic — no kernel is ever
                executed inside the PPO loop.
"""
from __future__ import annotations

import dataclasses

from repro.core import actions as A
from repro.core import cost_model, hardware, rules
from repro.core.kernel_ir import KernelProgram
from repro.core.micro_coding import MicroCoder, StructuredMicroCoder


# ---------------------------------------------------------------------------
# reward sources
# ---------------------------------------------------------------------------

class RewardSource:
    """Prices programs for reward shaping: ``cost(task, prog, target)``
    -> seconds.  Environments compute speedup deltas from these costs;
    swapping the source changes WHAT the policy is rewarded for
    (analytic model vs measured reality) without touching the shaping.
    """

    name = "base"

    def cost(self, task: KernelProgram, prog: KernelProgram,
             target=None) -> float:
        raise NotImplementedError


class AnalyticRewardSource(RewardSource):
    """The roofline cost model (optionally a pluggable drop-in)."""

    name = "analytic"

    def __init__(self, model=None):
        # duck-typed ``program_cost(prog, target)``; None = the
        # analytic module itself
        self.model = model if model is not None else cost_model

    def cost(self, task, prog, target=None) -> float:
        return self.model.program_cost(
            prog, hardware.resolve(target)).total_s


class CalibratedRewardSource(RewardSource):
    """Roofline scaled by measured per-(target, bottleneck) factors."""

    name = "calibrated"

    def __init__(self, calibration):
        from repro.measure.calibrate import CalibratedCostModel
        self.model = CalibratedCostModel(calibration)

    def cost(self, task, prog, target=None) -> float:
        return self.model.total_s(prog, hardware.resolve(target))


class LearnedRewardSource(RewardSource):
    """Prices via a ``LearnedCostModel`` (measure/learned.py): ridge on
    log-time over program/schedule/target features, analytic fallback
    for untrained / out-of-distribution programs — so an absent
    artifact makes this behave exactly like ``analytic``."""

    name = "learned"

    def __init__(self, model):
        # a LearnedCostModel instance, or an artifact path to load
        if isinstance(model, str):
            from repro.measure.learned import LearnedCostModel
            model = LearnedCostModel.load(model)
        self.model = model

    def cost(self, task, prog, target=None) -> float:
        return self.model.total_s(prog, hardware.resolve(target))


class MeasuredRewardSource(RewardSource):
    """Wall-clock rewards replayed from a persistent ``MeasureDB``.

    The DB's samples are indexed once by ``(task_fp, prog_fp, target)``;
    ``cost`` answers from that index and falls back to ``fallback``
    (default: analytic) for never-measured programs.  Strictly replay —
    this source never lowers or times anything, so PPO training over it
    is hermetic and deterministic given the DB contents.  Samples
    spanning more than one environment fingerprint are refused unless
    one is selected (``env_fp=``): wall times from incomparable
    environments must not compete inside one reward stream (same rule
    as ``measure.fit_calibration``).
    """

    name = "measured"

    def __init__(self, db, *, fallback: RewardSource | None = None,
                 env_fp: str | None = None):
        self.fallback = fallback if fallback is not None \
            else AnalyticRewardSource()
        self.index: dict[tuple[str, str, str], float] = {}
        envs: set[str] = set()
        for s in db.iter_samples(env_fp=env_fp):
            envs.add(s.env_fp)
            if len(envs) > 1:
                raise ValueError(
                    f"measurement DB spans {len(envs)} environment "
                    f"fingerprints ({sorted(envs)}); pass env_fp= to "
                    f"select one (MeasuredRewardSource(db, env_fp=...))")
            self.index[(s.task_fp, s.prog_fp, s.target)] = s.time_s
        self.hits = 0
        self.misses = 0

    def cost(self, task, prog, target=None) -> float:
        key = (task.fingerprint(), prog.fingerprint(),
               hardware.resolve(target).name)
        t = self.index.get(key)
        if t is not None:
            self.hits += 1
            return t
        self.misses += 1
        return self.fallback.cost(task, prog, target)


def get_reward_source(spec, *, db=None,
                      env_fp: str | None = None) -> RewardSource:
    """Name/instance -> ``RewardSource``.

    ``"analytic"`` | ``None`` -> the roofline; ``"calibrated"`` -> fit
    from ``db``'s samples; ``"measured"`` -> DB replay with a
    calibrated fallback (both require ``db``); ``"learned"`` -> fit a
    ``LearnedCostModel`` from ``db``'s program-embedding samples
    (requires ``db``); ``"learned:PATH"`` -> load a fitted artifact
    (missing file = analytic identity).  Instances pass through.
    """
    if spec is None or spec == "analytic":
        return AnalyticRewardSource()
    if isinstance(spec, RewardSource):
        return spec
    if isinstance(spec, str) and spec.startswith("learned"):
        from repro.measure.learned import (LearnedCostModel,
                                           fit_learned_model)
        if spec.startswith("learned:"):
            return LearnedRewardSource(
                LearnedCostModel.load(spec.split(":", 1)[1]))
        if spec != "learned":
            raise ValueError(f"unknown reward source {spec!r}")
        if db is None:
            raise ValueError("reward source 'learned' needs a "
                             "MeasureDB (db=...)")
        model = fit_learned_model(db.iter_samples(env_fp=env_fp),
                                  allow_mixed_envs=env_fp is None)
        return LearnedRewardSource(LearnedCostModel(model))
    if spec in ("calibrated", "measured"):
        if db is None:
            raise ValueError(f"reward source {spec!r} needs a "
                             f"MeasureDB (db=...)")
        from repro.measure.calibrate import fit_calibration
        cal = fit_calibration(db.iter_samples(env_fp=env_fp))
        calibrated = CalibratedRewardSource(cal)
        if spec == "calibrated":
            return calibrated
        return MeasuredRewardSource(db, fallback=calibrated,
                                    env_fp=env_fp)
    raise ValueError(f"unknown reward source {spec!r}; expected "
                     f"analytic|calibrated|measured or a RewardSource")


@dataclasses.dataclass
class EnvConfig:
    max_steps: int = 8
    penalty_compile: float = -0.4
    penalty_wrong: float = -0.8
    reward_valid: float = 0.1
    reward_speed_scale: float = 1.0
    decay_per_step: float = 0.1       # positive-reward decay
    decay_floor: float = 0.3
    curated_actions: bool = True      # False = "w/o AS" ablation
    extended_rules: bool = False      # True = non-default registry rules too


@dataclasses.dataclass
class StepResult:
    program: KernelProgram
    reward: float
    done: bool
    info: dict


class KernelEnv:
    """Live environment: applies actions through a MicroCoder.

    ``store`` (optional, a ``core.engine.TranspositionStore`` or anything
    with the same ``apply``/``cost`` duck type) memoizes rewrites and
    cost-model pricing by fingerprint, shared with ``OfflineTree`` and
    the pipeline — a visited (state, action) edge is never re-rewritten.
    """

    def __init__(self, task: KernelProgram, coder: MicroCoder | None = None,
                 cfg: EnvConfig | None = None, store=None, target=None,
                 reward_source: RewardSource | None = None):
        self.task = task
        self.coder = coder or StructuredMicroCoder()
        # None -> fresh config: a dataclass-instance default would be
        # one SHARED mutable object across every env ever constructed
        self.cfg = cfg if cfg is not None else EnvConfig()
        self.store = store
        # the chip rewards are priced against (None = registry default);
        # rewrite legality stays target-independent (DESIGN.md §9)
        self.target = hardware.resolve(target)
        # pricing seam for rewards: when set it OVERRIDES the store's
        # analytic memo (the store still memoizes rewrites/oracle runs
        # — only what the reward is worth changes)
        self.reward_source = reward_source
        self.baseline_s = self._cost(task)

    def _cost(self, prog: KernelProgram) -> float:
        if self.reward_source is not None:
            return self.reward_source.cost(self.task, prog, self.target)
        if self.store is not None:
            return self.store.cost(prog, self.target)
        return cost_model.program_cost(prog, self.target).total_s

    def _apply(self, action: A.Action):
        if self.store is not None:
            return self.store.apply(self.coder, self.state, action)
        return self.coder.apply(self.state, action)

    def reset(self) -> KernelProgram:
        self.state = self.task
        self.t = 0
        self.prev_s = self.baseline_s
        return self.state

    def candidates(self, state: KernelProgram | None = None
                   ) -> list[A.Action]:
        state = state or self.state
        enum = (A.candidate_actions if self.cfg.curated_actions
                else A.unrestricted_actions)
        return enum(state, target=self.target,
                    extended=self.cfg.extended_rules)

    def _decay(self) -> float:
        return max(self.cfg.decay_floor,
                   1.0 - self.cfg.decay_per_step * self.t)

    def step(self, action: A.Action) -> StepResult:
        cfg = self.cfg
        self.t += 1
        done = self.t >= cfg.max_steps
        if rules.is_terminal(action):
            final = self.baseline_s / self.prev_s
            r = 0.25 * max(0.0, final - 1.0)
            return StepResult(self.state, r, True,
                              {"status": "stop", "speedup": final})
        res = self._apply(action)
        if res.status == "compile_error":
            return StepResult(self.state, cfg.penalty_compile, done,
                              {"status": res.status, "detail": res.detail})
        if res.status == "wrong_result":
            return StepResult(self.state, cfg.penalty_wrong, done,
                              {"status": res.status})
        new_s = self._cost(res.program)
        delta = self.prev_s / new_s - 1.0          # speedup vs prev step
        r = cfg.reward_valid + cfg.reward_speed_scale * max(
            min(delta, 3.0), -0.5)
        r *= self._decay()
        self.state = res.program
        self.prev_s = new_s
        return StepResult(self.state, r, done,
                          {"status": "ok",
                           "speedup": self.baseline_s / new_s})


# ---------------------------------------------------------------------------
# offline tree
# ---------------------------------------------------------------------------

def action_key(a: A.Action) -> str:
    return f"{a.kind}|{a.region}|{a.param!r}"


@dataclasses.dataclass
class TreeNode:
    program: KernelProgram
    cost_s: float
    children: dict = dataclasses.field(default_factory=dict)
    # action_key -> (child_fp | None, status)


class OfflineTree:
    """Materialized transition cache for offline policy training.

    When given a ``store`` (``core.engine.TranspositionStore``), the tree
    interns and expands against that shared backing store, so live envs,
    pipelines and other trees reuse its transitions (and vice versa).
    """

    def __init__(self, task: KernelProgram, store=None, target=None,
                 reward_source: RewardSource | None = None):
        self.task = task
        self.store = store
        self.target = hardware.resolve(target)
        # node costs — what OfflineEnv rewards replay — come from the
        # reward source when one is given; the store keeps memoizing
        # the transitions either way
        self.reward_source = reward_source
        self.nodes: dict[str, TreeNode] = {}
        self.root = self._intern(task)

    def _node_cost(self, prog: KernelProgram) -> float:
        if self.reward_source is not None:
            return self.reward_source.cost(self.task, prog, self.target)
        if self.store is not None:
            return self.store.cost(prog, self.target)
        return cost_model.program_cost(prog, self.target).total_s

    def _intern(self, prog: KernelProgram) -> str:
        if self.store is not None:
            fp = self.store.intern(prog, self.target)
            if fp not in self.nodes:
                self.nodes[fp] = TreeNode(prog, self._node_cost(prog))
            return fp
        fp = prog.fingerprint()
        if fp not in self.nodes:
            self.nodes[fp] = TreeNode(prog, self._node_cost(prog))
        return fp

    def expand(self, fp: str, action: A.Action,
               coder: MicroCoder) -> tuple[str | None, str]:
        node = self.nodes[fp]
        k = action_key(action)
        if k in node.children:
            return node.children[k]
        if self.store is not None:
            res = self.store.apply(coder, node.program, action)
        else:
            res = coder.apply(node.program, action)
        child = self._intern(res.program) if res.status == "ok" and \
            not rules.is_terminal(action) else None
        node.children[k] = (child, res.status)
        return node.children[k]

    def materialized_actions(self, fp: str) -> list[tuple[A.Action, str]]:
        node = self.nodes[fp]
        out = []
        import ast
        for k, (_child, status) in node.children.items():
            kind, region, param = k.split("|", 2)
            out.append((A.Action(kind, region,
                                 ast.literal_eval(param)), status))
        return out

    @property
    def size(self) -> int:
        return len(self.nodes)


class OfflineEnv:
    """Replays an OfflineTree with the same reward shaping as KernelEnv.

    The candidate set at each state is the tree's materialized actions
    (plus stop) — the policy learns from offline data exactly as in the
    paper's environment design.
    """

    def __init__(self, tree: OfflineTree, cfg: EnvConfig | None = None):
        self.tree = tree
        self.cfg = cfg if cfg is not None else EnvConfig()
        self.baseline_s = tree.nodes[tree.root].cost_s

    def reset(self) -> str:
        self.fp = self.tree.root
        self.t = 0
        self.prev_s = self.baseline_s
        return self.fp

    def program(self, fp: str | None = None) -> KernelProgram:
        return self.tree.nodes[fp or self.fp].program

    def candidates(self) -> list[A.Action]:
        acts = [a for a, _ in
                self.tree.materialized_actions(self.fp)]
        if not any(rules.is_terminal(a) for a in acts):
            acts.append(A.STOP)
        return acts

    def step(self, action: A.Action) -> StepResult:
        cfg = self.cfg
        self.t += 1
        done = self.t >= cfg.max_steps
        decay = max(cfg.decay_floor, 1.0 - cfg.decay_per_step * self.t)
        if rules.is_terminal(action):
            final = self.baseline_s / self.prev_s
            r = 0.25 * max(0.0, final - 1.0)
            return StepResult(self.program(), r, True,
                              {"status": "stop", "speedup": final})
        child, status = self.tree.nodes[self.fp].children.get(
            action_key(action), (None, "compile_error"))
        if status == "compile_error":
            return StepResult(self.program(), cfg.penalty_compile, done,
                              {"status": status})
        if status == "wrong_result":
            return StepResult(self.program(), cfg.penalty_wrong, done,
                              {"status": status})
        new_s = self.tree.nodes[child].cost_s
        delta = self.prev_s / new_s - 1.0
        r = (cfg.reward_valid + cfg.reward_speed_scale *
             max(min(delta, 3.0), -0.5)) * decay
        self.fp = child
        self.prev_s = new_s
        return StepResult(self.program(), r, done,
                          {"status": "ok",
                           "speedup": self.baseline_s / new_s})
