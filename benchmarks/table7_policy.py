"""Paper Table 7 — Macro Thinking ablation grid:
  w/ policy + AS      : trained policy (x2 backbone sizes)
  w/o policy + AS     : random / untrained-LM over the curated space
  w/o policy + w/o AS : untrained-LM over unrestricted proposals
on a 10%-style subset of the benchmark tasks (paper's protocol)."""
from __future__ import annotations

from .common import eval_mode, fmt_row
from repro.core import MacroPolicy
from repro.core import tasks as T


def _subset():
    return [T.kb_level1()[0], T.kb_level1()[5], T.kb_level2()[0],
            T.kb_level2()[3], T.kb_level3()[0]]


def run(policy, small_policy=None) -> list[str]:
    suite = _subset()
    rows = []
    rows.append(fmt_row("table7", "w_policy_AS/ds-coder-proxy",
                        eval_mode(suite, "policy", policy)))
    if small_policy is not None:
        rows.append(fmt_row("table7", "w_policy_AS/llama-proxy-small",
                            eval_mode(suite, "policy", small_policy)))
    rows.append(fmt_row("table7", "wo_policy_AS/random",
                        eval_mode(suite, "random", None)))
    rows.append(fmt_row("table7", "wo_policy_AS/untrained-lm",
                        eval_mode(suite, "untrained", MacroPolicy())))
    rows.append(fmt_row("table7", "wo_policy_woAS/untrained-lm",
                        eval_mode(suite, "untrained", MacroPolicy(),
                                  curated=False)))
    return rows
