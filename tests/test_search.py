"""Search-strategy properties: correctness and never-worse-than-greedy."""
import pytest
from _hyp import given, settings, strategies as st

from repro.core import (MTMCPipeline, StructuredMicroCoder,
                        TranspositionStore, get_strategy)
from repro.core import tasks as T
from repro.core.search import (AnnealedSearch, BeamSearch, GreedySearch,
                               STRATEGIES)

# one store for the whole module: strategies are designed to share
# transition/cost/oracle memos, and the never-regress property is
# stated "on the same store"
STORE = TranspositionStore()
CODER = StructuredMicroCoder()
SUITE = T.kb_level1() + T.kb_level2() + T.kb_level3()


def _greedy(task, target=None, max_steps=8):
    return GreedySearch().search(task, coder=CODER, store=STORE,
                                 target=target, max_steps=max_steps)


# ---------------------------------------------------------------------------
# the property the ISSUE names: every strategy's program passes the
# oracle and costs no more than the greedy baseline on the same store
# ---------------------------------------------------------------------------

@settings(max_examples=30, deadline=None)
@given(ti=st.integers(0, len(SUITE) - 1),
       sname=st.sampled_from(sorted(STRATEGIES)),
       seed=st.integers(0, 3),
       target=st.sampled_from(["tpu_v5e", "tpu_v4", "gpu_a100"]))
def test_strategy_never_regresses_and_stays_correct(ti, sname, seed,
                                                    target):
    task = SUITE[ti]
    g = _greedy(task, target)
    out = get_strategy(sname).search(task, coder=CODER, store=STORE,
                                     target=target, max_steps=8,
                                     seed=seed)
    assert out.cost_s <= g.cost_s * (1 + 1e-12), (task.name, sname)
    assert out.cost_s <= out.baseline_s * (1 + 1e-12)
    assert STORE.check(task, out.program), (task.name, sname)


def test_beam_strictly_improves_on_fusion_order_traps():
    """The L2 ffn chains embed an up-vs-down fusion ordering decision
    greedy gets wrong; beam must win them on the default target."""
    wins = 0
    for task in T.kb_level2():
        if not task.name.startswith("L2_mlp"):
            continue
        g = _greedy(task)
        b = BeamSearch().search(task, coder=CODER, store=STORE,
                                max_steps=8)
        wins += b.cost_s < g.cost_s
    assert wins >= 3


def test_greedy_matches_greedy_cost_mode():
    """GreedySearch is the seed's greedy_cost descent, factored out:
    same final modeled cost on every KB task."""
    for task in SUITE:
        res = MTMCPipeline(mode="greedy_cost", max_steps=8, store=STORE,
                           validate=False).optimize(task)
        out = _greedy(task)
        assert abs(STORE.cost(res.program) - out.cost_s) <= \
            1e-12 * max(out.cost_s, 1e-30), task.name


def test_beam_cap_collision_keeps_dropped_children_rediscoverable():
    """Regression: ``BeamSearch`` marked every priced child as seen even
    when the width/per_parent caps then dropped it from the frontier,
    permanently blocking rediscovery of that program via another path
    at a later depth.  On this crafted graph the global best sits in
    the subtree of a depth-1 cap casualty that only a depth-3 detour
    can re-reach:

        R(10) -> A(5)   -> C(5.5) -> B      (rediscovery route)
              -> B(6)   -> D(1)            (global best; B dropped at
                                             depth 1 by width=1)
    """
    from repro.core import search as S
    from repro.core.micro_coding import ApplyResult

    class _Prog:
        def __init__(self, name):
            self.name = name

        def fingerprint(self):
            return self.name

    costs = {"R": 10.0, "A": 5.0, "B": 6.0, "C": 5.5, "D": 1.0}
    edges = {("R", "a"): "A", ("R", "b"): "B", ("A", "c"): "C",
             ("C", "b2"): "B", ("B", "d"): "D"}
    acts = {"R": ["a", "b"], "A": ["c"], "C": ["b2"], "B": ["d"],
            "D": []}
    progs = {n: _Prog(n) for n in costs}

    class _Store:          # duck-typed: search only needs apply/cost
        def apply(self, coder, prog, action):
            child = edges.get((prog.name, action.region))
            if child is None:
                return ApplyResult("compile_error", None, "no edge")
            return ApplyResult("ok", progs[child], "")

        def cost(self, prog, target=None):
            return costs[prog.name]

    store = _Store()
    real_cands = S.A.candidate_actions
    S.A.candidate_actions = lambda prog, target=None, extended=False: [
        S.A.Action("tiling", r, ()) for r in acts[prog.name]]
    try:
        g = GreedySearch().search(progs["R"], coder=None, store=store,
                                  max_steps=4)
        b = BeamSearch(width=1, per_parent=2).search(
            progs["R"], coder=None, store=store, max_steps=4)
    finally:
        S.A.candidate_actions = real_cands
    assert g.cost_s == 5.0               # greedy stalls at A's plateau
    assert b.cost_s == 1.0               # beam re-reaches B, finds D
    assert b.program.name == "D"


@settings(max_examples=25, deadline=None)
@given(ti=st.integers(0, len(SUITE) - 1),
       seed=st.integers(0, 3),
       target=st.sampled_from(["tpu_v5e", "gpu_a100"]))
def test_policy_search_untrained_never_worse_than_greedy(ti, seed,
                                                         target):
    """The ISSUE's safety property: an UNTRAINED policy ranking the
    frontier must never cost PolicySearch correctness or the greedy
    floor — the greedy backbone is folded into the search, so a
    useless ranker degrades to greedy, not below it."""
    from repro.core import MacroPolicy
    from repro.core.search import PolicySearch
    task = SUITE[ti]
    g = _greedy(task, target)
    out = PolicySearch().search(task, coder=CODER, store=STORE,
                                target=target, max_steps=8, seed=seed,
                                policy=MacroPolicy())
    assert out.cost_s <= g.cost_s * (1 + 1e-12), task.name
    assert STORE.check(task, out.program), task.name


def test_policy_search_expands_fewer_nodes_than_beam():
    """The budget claim at test scale: on the same store and depth,
    PolicySearch's pruned frontier expands strictly fewer nodes than
    beam while keeping the greedy floor (quality is gated for the
    TRAINED policy in benchmarks/table7_policy.py)."""
    from repro.core import MacroPolicy
    from repro.core.search import PolicySearch
    pol = MacroPolicy()
    for task in (T.kb_level2()[0], T.kb_level3()[0]):
        b = BeamSearch().search(task, coder=CODER, store=STORE,
                                max_steps=8, extended=True)
        p = PolicySearch().search(task, coder=CODER, store=STORE,
                                  max_steps=8, extended=True,
                                  policy=pol)
        assert p.n_expanded < b.n_expanded, task.name
        assert p.cost_s <= _greedy(task).cost_s * (1 + 1e-12)


def test_anneal_restart_zero_is_greedy():
    task = T.kb_level2()[0]
    a = AnnealedSearch(restarts=1).search(task, coder=CODER,
                                          store=STORE, max_steps=8)
    g = _greedy(task)
    assert a.cost_s == pytest.approx(g.cost_s, rel=1e-12)


# ---------------------------------------------------------------------------
# pipeline / engine integration
# ---------------------------------------------------------------------------

def test_pipeline_strategy_param():
    task = T.kb_level2()[0]
    for sname in sorted(STRATEGIES):
        r = MTMCPipeline(strategy=sname, max_steps=8,
                         store=STORE).optimize(task)
        assert r.correct and r.speedup >= 1.0 - 1e-12
        assert r.task == task.name


def test_pipeline_strategy_without_store_builds_one():
    r = MTMCPipeline(strategy="beam", max_steps=4).optimize(
        T.kb_level1()[0])
    assert r.correct and r.speedup >= 1.0 - 1e-12


def test_engine_strategy_and_target_config():
    from repro.core import EvalEngine
    eng = EvalEngine(None, store=STORE, mode="greedy_cost",
                     strategy="beam", target="gpu_a100", max_steps=6)
    m = eng.evaluate_suite(T.kb_level2()[:3])
    assert m["accuracy"] == 1.0
    assert m["mean_speedup"] >= 1.0 - 1e-12


def test_unknown_strategy_rejected():
    with pytest.raises(KeyError):
        get_strategy("dijkstra")
