"""Task suites mirroring the paper's benchmarks (Table 1).

KB-L1: single ops; KB-L2: fused-op subgraphs; KB-L3: network blocks —
our KernelBench-like suite.  TB-T: PyTorch-aligned common ops; TB-G:
real-world kernels (flash attn, rwkv chunk, moe dispatch) — the
TritonBench-like suite.  ``TRAIN_TASKS`` are disjoint size/pattern
variants used only for policy training (the paper trains on 60k offline
trajectories with NO benchmark instances — same discipline here).

Every task is a naive, unfused, default-tiled KernelProgram; its initial
cost is the "PyTorch Eager" analogue (generic per-op kernels, DESIGN.md
§7) and speedups are measured against it.
"""
from __future__ import annotations

from repro.core.kernel_ir import KernelProgram, OpNode, TensorSpec, \
    chain_program


def _attn_program(name, B, S, H, hd, causal=True) -> KernelProgram:
    nodes = (
        OpNode("scores", "qk_scores", ("q", "k"),
               (("causal", causal),)),
        OpNode("probs", "softmax", ("scores",)),
        OpNode("out", "av", ("probs", "v")),
    )
    return KernelProgram(
        name=name,
        inputs=(("q", TensorSpec((B, S, H, hd))),
                ("k", TensorSpec((B, S, H, hd))),
                ("v", TensorSpec((B, S, H, hd)))),
        nodes=nodes, outputs=("out",),
        fusion_groups=(("scores",), ("probs",), ("out",)),
        schedules=(("scores", _ms()), ("probs", _ms("elementwise")),
                   ("out", _ms())))


def _ms(kind="matmul"):
    from repro.kernels.schedule import default_schedule
    return default_schedule(kind)


def _ffn_chain(name, M, D, F, act, D2) -> KernelProgram:
    """matmul -> bias -> activation -> matmul (KernelBench-L2 staple)."""
    return chain_program(name, {"x": (M, D), "w1": (D, F), "b1": (F,),
                                "w2": (F, D2)},
                         [("h", "matmul", ("x", "w1")),
                          ("hb", "bias", ("h", "b1")),
                          ("hg", act, ("hb",)),
                          ("y", "matmul", ("hg", "w2"))])


def _mlp_block(name, M, D, F) -> KernelProgram:
    return chain_program(name, {"x": (M, D), "w1": (D, F), "b1": (F,),
                                "w2": (F, D), "scale": (D,)},
                         [("h", "matmul", ("x", "w1")),
                          ("hb", "bias", ("h", "b1")),
                          ("hg", "gelu", ("hb",)),
                          ("y", "matmul", ("hg", "w2"))])


def _transformer_block(name, S, D, H) -> KernelProgram:
    hd = D // H
    B = 1
    nodes = (
        OpNode("n1", "rmsnorm", ("x2d", "sc1")),
        OpNode("q2", "matmul", ("n1", "wq")),
        OpNode("k2", "matmul", ("n1", "wk")),
        OpNode("v2", "matmul", ("n1", "wv")),
        # (reshape to heads is layout-free in the IR: 4D inputs given)
        OpNode("scores", "qk_scores", ("q4", "k4"), (("causal", True),)),
        OpNode("probs", "softmax", ("scores",)),
        OpNode("attn", "av", ("probs", "v4")),
        OpNode("proj", "matmul", ("attn2d", "wo")),
        OpNode("res1", "add", ("x2d", "proj")),
        OpNode("n2", "rmsnorm", ("res1", "sc2")),
        OpNode("ff1", "matmul", ("n2", "wu")),
        OpNode("ffg", "gelu", ("ff1",)),
        OpNode("ff2", "matmul", ("ffg", "wd")),
        OpNode("res2", "add", ("res1", "ff2")),
    )
    inputs = {
        "x2d": (S, D), "sc1": (D,), "sc2": (D,),
        "wq": (D, D), "wk": (D, D), "wv": (D, D), "wo": (D, D),
        "q4": (B, S, H, hd), "k4": (B, S, H, hd), "v4": (B, S, H, hd),
        "attn2d": (S, D), "wu": (D, 4 * D), "wd": (4 * D, D),
    }
    groups = tuple((n.name,) for n in nodes)
    scheds = tuple((n.name, _ms("matmul" if "matmul" in n.op or
                                n.op in ("qk_scores", "av") else
                                "elementwise")) for n in nodes)
    return KernelProgram(
        name=name,
        inputs=tuple((k, TensorSpec(v)) for k, v in inputs.items()),
        nodes=nodes, outputs=("res2",), fusion_groups=groups,
        schedules=scheds)


def _rwkv_task(name, B, T, H, dk) -> KernelProgram:
    return chain_program(
        name,
        {"r": (B, T, H, dk), "kk": (B, T, H, dk), "v": (B, T, H, dk),
         "w_decay": (B, T, H, dk), "u": (H, dk)},
        [("wkv", "rwkv_chunk", ("r", "kk", "v", "w_decay", "u"))])


def _ssm_task(name, B, T, H, P, N) -> KernelProgram:
    return chain_program(
        name,
        {"x": (B, T, H, P), "x_dt": (B, T, H), "a_A": (H,),
         "bmat": (B, T, N), "cmat": (B, T, N)},
        [("y", "ssm_chunk", ("x", "x_dt", "a_A", "bmat", "cmat"))])


def _moe_task(name, E, C, D, F) -> KernelProgram:
    return chain_program(
        name, {"xg": (E, C, D), "wg": (E, D, F)},
        [("h", "grouped_matmul", ("xg", "wg")),
         ("y", "silu", ("h",))])


# ---------------------------------------------------------------------------
# KernelBench-like
# ---------------------------------------------------------------------------

def kb_level1() -> list[KernelProgram]:
    t = []
    for i, (m, k, n) in enumerate([(512, 512, 512), (1024, 512, 256),
                                   (256, 2048, 512), (2048, 256, 512)]):
        t.append(chain_program(f"L1_matmul_{i}",
                               {"a": (m, k), "b": (k, n)},
                               [("y", "matmul", ("a", "b"))]))
    t.append(chain_program("L1_softmax", {"x": (1024, 1024)},
                           [("y", "softmax", ("x",))]))
    t.append(chain_program("L1_rmsnorm", {"x": (2048, 1024),
                                          "s": (1024,)},
                           [("y", "rmsnorm", ("x", "s"))]))
    t.append(chain_program("L1_relu", {"x": (2048, 2048)},
                           [("y", "relu", ("x",))]))
    t.append(chain_program("L1_square_sum",
                           {"x": (2048, 1024)},
                           [("sq", "square", ("x",)),
                            ("y", "row_sum", ("sq",))]))
    t.append(_attn_program("L1_attention", 2, 512, 4, 64))
    t.append(_rwkv_task("L1_rwkv", 2, 256, 4, 32))
    return t


def kb_level2() -> list[KernelProgram]:
    t = []
    t.append(chain_program("L2_gemm_bias_relu",
                           {"a": (512, 1024), "b": (1024, 512),
                            "bias0": (512,)},
                           [("y0", "matmul", ("a", "b")),
                            ("y1", "bias", ("y0", "bias0")),
                            ("y", "relu", ("y1",))]))
    t.append(chain_program("L2_gemm_max",
                           {"a": (1024, 512), "b": (512, 1024)},
                           [("y0", "matmul", ("a", "b")),
                            ("y", "row_max", ("y0",))]))
    t.append(chain_program("L2_norm_gemm",
                           {"x": (512, 1024), "s": (1024,),
                            "w": (1024, 1024)},
                           [("n", "rmsnorm", ("x", "s")),
                            ("y", "matmul", ("n", "w"))]))
    t.append(chain_program("L2_swiglu",
                           {"x": (512, 512), "wg": (512, 2048),
                            "wu": (512, 2048), "wd": (2048, 512)},
                           [("g", "matmul", ("x", "wg")),
                            ("gs", "silu", ("g",)),
                            ("u", "matmul", ("x", "wu")),
                            ("gu", "mul", ("gs", "u")),
                            ("y", "matmul", ("gu", "wd"))]))
    t.append(_mlp_block("L2_mlp", 512, 1024, 4096))
    # matmul->bias->activation->matmul chains at varied shapes — the
    # dominant fused-subgraph family of real KernelBench L2.  The fusion
    # ORDER is a genuine search decision here: the activation can fuse
    # up into its producer matmul or down into its consumer, and the
    # wrong (locally-best) choice forecloses the better one.
    t.append(_ffn_chain("L2_mlp_silu", 512, 768, 3072, "silu", 768))
    t.append(_ffn_chain("L2_mlp_gelu_proj", 512, 1024, 2048, "gelu",
                        2048))
    t.append(_ffn_chain("L2_mlp_relu_sq", 1024, 1024, 2048, "relu",
                        1024))
    t.append(_moe_task("L2_moe_mm", 4, 256, 512, 1024))
    return t


def kb_level3() -> list[KernelProgram]:
    return [
        _transformer_block("L3_block_small", 512, 512, 8),
        _transformer_block("L3_block_wide", 256, 1024, 8),
        _ssm_task("L3_ssm_net", 2, 512, 4, 64, 16),
        _rwkv_task("L3_rwkv_net", 2, 512, 8, 64),
    ]


# ---------------------------------------------------------------------------
# TritonBench-like
# ---------------------------------------------------------------------------

def tb_t() -> list[KernelProgram]:
    """PyTorch-aligned common ops."""
    t = []
    for i, (m, k, n) in enumerate([(768, 768, 768), (1536, 384, 768)]):
        t.append(chain_program(f"T_gemm_{i}", {"a": (m, k), "b": (k, n)},
                               [("y", "matmul", ("a", "b"))]))
    t.append(chain_program("T_layernormish", {"x": (4096, 768),
                                              "s": (768,)},
                           [("y", "rmsnorm", ("x", "s"))]))
    t.append(chain_program("T_gelu_gemm",
                           {"a": (768, 768), "b": (768, 3072)},
                           [("y0", "matmul", ("a", "b")),
                            ("y", "gelu", ("y0",))]))
    t.append(chain_program("T_softmax_wide", {"x": (512, 4096)},
                           [("y", "softmax", ("x",))]))
    return t


def tb_g() -> list[KernelProgram]:
    """Real-world cases."""
    return [
        _attn_program("G_flash_causal", 2, 1024, 8, 64),
        _attn_program("G_flash_bidir", 2, 512, 8, 64, causal=False),
        _rwkv_task("G_rwkv_chunk", 2, 1024, 8, 64),
        _ssm_task("G_mamba_scan", 2, 1024, 8, 64, 16),
        _moe_task("G_moe_dispatch", 8, 512, 1024, 2048),
        _transformer_block("G_minigpt_block", 1024, 768, 12),
    ]


# ---------------------------------------------------------------------------
# extension suite — workloads opened by the non-default registry rules
# ---------------------------------------------------------------------------

def ext_tasks() -> list[KernelProgram]:
    """Decode-shaped skinny-M matmuls (the ``split_k`` rule's domain:
    classic tile presets cannot even divide M, and the un-split stream
    under-fills the pipeline) and weight-heavy bf16-friendly chains
    (the ``dtype`` rule's domain: memory-bound on operand bytes that a
    bf16 output spec halves).  Kept out of the KB/TB suites so their
    committed benchmark rows stay comparable across PRs."""
    t = []
    # skinny-M: batch-4/8 decode GEMMs, long reduction dims
    for name, m, k, n in [("EXT_decode_head", 4, 2048, 1024),
                          ("EXT_decode_qkv", 8, 1024, 1536)]:
        t.append(chain_program(name, {"x": (m, k), "w": (k, n)},
                               [("y", "matmul", ("x", "w"))]))
    t.append(chain_program("EXT_decode_ffn",
                           {"x": (4, 1024), "w1": (1024, 4096),
                            "b1": (4096,)},
                           [("h", "matmul", ("x", "w1")),
                            ("hb", "bias", ("h", "b1")),
                            ("y", "silu", ("hb",))]))
    # bf16-friendly: weight-streaming-bound matmul chains
    t.append(_ffn_chain("EXT_mlp_bf16", 256, 2048, 8192, "gelu", 2048))
    t.append(chain_program("EXT_proj_bf16",
                           {"x": (512, 4096), "w": (4096, 1024)},
                           [("h", "matmul", ("x", "w")),
                            ("y", "gelu", ("h",))]))
    t.append(_ffn_chain("EXT_gate_bf16", 384, 1536, 6144, "silu", 1536))
    return t


# ---------------------------------------------------------------------------
# open-space suite — outside the closed rule space's reachable set
# ---------------------------------------------------------------------------

def open_tasks() -> list[KernelProgram]:
    """Ragged-dimension fused chains no registered rule template covers:
    every dimension is chosen so NO closed tile preset (the 64..512
    lane-ladder ``rules.tile_presets`` enumerates) divides it, while
    lane-aligned divisors DO exist (e.g. 360 -> 8/24/40/72/120/360).
    The structured coder therefore compile-errors every tiling proposal
    and the naive default schedule is the best the closed space can do;
    an LLM-backed micro-coder can still land a verified custom tiling.
    The ``table11_coder.py`` open-space gate runs on these (kept out of
    KB/TB so committed benchmark rows stay comparable across PRs).

    Initial schedules carry NO explicit blocks: the stock 128-block
    defaults do not divide ragged dims, so a default-tiled baseline
    would be analyzer-illegal before any rewrite.  Blockless schedules
    are legal everywhere and the cost model prices them at the implicit
    128 defaults, so a landed custom tiling still shows up as a real
    modeled gain."""
    t = []
    # ragged fused MLP: matmul -> bias -> gelu -> matmul on 360/600/840
    t.append(chain_program("OPEN_ragged_mlp",
                           {"x": (360, 600), "w1": (600, 840),
                            "b1": (840,), "w2": (840, 360)},
                           [("h", "matmul", ("x", "w1")),
                            ("hb", "bias", ("h", "b1")),
                            ("hg", "gelu", ("hb",)),
                            ("y", "matmul", ("hg", "w2"))]))
    # ragged plain GEMM: 440 x 1000 x 520
    t.append(chain_program("OPEN_ragged_gemm",
                           {"a": (440, 1000), "b": (1000, 520)},
                           [("y", "matmul", ("a", "b"))]))
    return [p.replace(schedules=tuple(
        (root, s.replace(blocks=())) for root, s in p.schedules))
        for p in t]


# ---------------------------------------------------------------------------
# policy-training tasks (disjoint from ALL benchmark instances)
# ---------------------------------------------------------------------------

def train_tasks() -> list[KernelProgram]:
    t = []
    for i, (m, k, n) in enumerate([(384, 640, 384), (896, 384, 640),
                                   (640, 896, 256), (1280, 384, 384),
                                   (384, 384, 1280), (768, 640, 896)]):
        t.append(chain_program(f"TR_matmul_{i}", {"a": (m, k),
                                                  "b": (k, n)},
                               [("y", "matmul", ("a", "b"))]))
    for i, (m, k, n) in enumerate([(640, 384, 896), (384, 896, 640)]):
        t.append(chain_program(f"TR_gemm_gelu_{i}",
                               {"a": (m, k), "b": (k, n), "bias0": (n,)},
                               [("y0", "matmul", ("a", "b")),
                                ("y1", "bias", ("y0", "bias0")),
                                ("y", "gelu", ("y1",))]))
    t.append(chain_program("TR_gemm_max", {"a": (896, 640),
                                           "b": (640, 896)},
                           [("y0", "matmul", ("a", "b")),
                            ("y", "row_max", ("y0",))]))
    t.append(chain_program("TR_norm_gemm",
                           {"x": (640, 896), "s": (896,),
                            "w": (896, 640)},
                           [("n", "rmsnorm", ("x", "s")),
                            ("y", "matmul", ("n", "w"))]))
    t.append(_attn_program("TR_attn_a", 2, 384, 4, 64))
    t.append(_attn_program("TR_attn_b", 1, 640, 8, 64))
    t.append(_mlp_block("TR_mlp", 384, 640, 2560))
    t.append(_rwkv_task("TR_rwkv", 2, 384, 4, 64))
    t.append(_ssm_task("TR_ssm", 2, 384, 4, 64, 16))
    t.append(_moe_task("TR_moe", 4, 384, 640, 1280))
    t.append(_transformer_block("TR_block", 384, 640, 8))
    return t


SUITES = {"KB-L1": kb_level1, "KB-L2": kb_level2, "KB-L3": kb_level3,
          "TB-T": tb_t, "TB-G": tb_g, "EXT": ext_tasks,
          "OPEN": open_tasks}
