"""Param-tree makers.

A model's parameter tree is declared once as ``param_tree(cfg, make)`` where
``make(name, shape, axes, init)`` is called per leaf.  The three makers:

  * ``init_maker``      -> real arrays (smoke tests / examples)
  * ``abstract_maker``  -> jax.ShapeDtypeStruct (dry-run, no allocation)
  * ``pspec_maker``     -> PartitionSpec from logical axes (sharding)
"""
from __future__ import annotations

from collections.abc import Callable
from typing import Any

import jax
import jax.numpy as jnp

from repro.dist.sharding import ShardingRules
from repro.models import layers

TreeFn = Callable[..., Any]


def init_maker(key: jax.Array, dtype: Any) -> TreeFn:
    def make(name, shape, axes, init=None):
        init = init or layers.normal_init()
        return init(layers.fold_key(key, name), shape, dtype)
    return make


def abstract_maker(dtype: Any) -> TreeFn:
    def make(name, shape, axes, init=None):
        return jax.ShapeDtypeStruct(shape, dtype)
    return make


def pspec_maker(rules: ShardingRules) -> TreeFn:
    def make(name, shape, axes, init=None):
        return rules.spec(shape, axes)
    return make


def sharding_maker(rules: ShardingRules) -> TreeFn:
    def make(name, shape, axes, init=None):
        return rules.sharding(shape, axes)
    return make


def build(param_tree: Callable[[TreeFn], Any], *, mode: str,
          key: jax.Array | None = None, dtype: Any = jnp.float32,
          rules: ShardingRules | None = None) -> Any:
    if mode == "init":
        return param_tree(init_maker(key, dtype))
    if mode == "abstract":
        return param_tree(abstract_maker(dtype))
    if mode == "pspec":
        return param_tree(pspec_maker(rules))
    if mode == "sharding":
        return param_tree(sharding_maker(rules))
    raise ValueError(mode)
