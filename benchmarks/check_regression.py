"""CI accuracy gate: fail if any suite's execute-accuracy regressed.

Compares a freshly produced ``benchmarks.csv`` against the committed
baseline: for every row name present in BOTH files whose ``derived``
column carries an ``acc=`` field, the new accuracy must be >= the
baseline's (within a 1e-9 float-print slack).  Modeled speedups are
deliberately NOT gated — they move whenever the cost model or search
deepens; execute accuracy is the correctness contract.

  python -m benchmarks.check_regression <baseline.csv> <new.csv>
"""
from __future__ import annotations

import re
import sys

_ACC = re.compile(r"(?:^|;)acc=([0-9.]+)")


def parse_accuracies(path: str) -> dict[str, float]:
    out: dict[str, float] = {}
    with open(path) as f:
        for line in f:
            line = line.strip()
            if not line or line.startswith(("name,", "#")):
                continue
            parts = line.split(",", 2)
            if len(parts) < 3:
                continue
            m = _ACC.search(parts[2])
            if m:
                out[parts[0]] = float(m.group(1))
    return out


def main(argv: list[str]) -> int:
    if len(argv) != 3:
        print(__doc__)
        return 2
    base = parse_accuracies(argv[1])
    new = parse_accuracies(argv[2])
    shared = sorted(set(base) & set(new))
    if not shared:
        print(f"error: no comparable rows between {argv[1]} ({len(base)} "
              f"acc rows) and {argv[2]} ({len(new)} acc rows)")
        return 2
    drops = [(n, base[n], new[n]) for n in shared
             if new[n] < base[n] - 1e-9]
    print(f"compared execute-accuracy on {len(shared)} rows "
          f"({len(base) - len(shared)} baseline-only, "
          f"{len(new) - len(shared)} new-only)")
    for name, b, n in drops:
        print(f"REGRESSION {name}: acc {b:.3f} -> {n:.3f}")
    if drops:
        return 1
    print("no execute-accuracy regressions")
    return 0


if __name__ == "__main__":
    raise SystemExit(main(sys.argv))
