"""Analytic roofline cost model (tier-3 reward source), multi-target.

Prices a ``KernelProgram`` the way the dry-run roofline prices a whole
training step: per fused kernel, time = max(compute, HBM) under the
schedule's tiling/ordering/pipelining, plus launch overhead per kernel.
All four semantic actions have first-order effects here:

  Tiling     — blocked-matmul re-read traffic  A*(N/bn) + B*(M/bm); flash
               K/V re-read per q-block; MXU alignment efficiency;
  Fusion     — intermediates stay in VMEM (no HBM round-trip), one launch;
  Pipeline   — depth 1: compute + memory serialize; depth>=2: overlap;
  Reordering — K-not-innermost matmul pays an output-revisit HBM term.

Hardware constants come from a ``HardwareTarget`` (``core/hardware.py``):
peak matmul/vector FLOP/s, HBM bandwidth, tile-alignment geometry and
launch overhead, so any program can be priced against any registered
chip.  The default target is tpu_v5e with the §Roofline constants
(197 TFLOP/s bf16, 819 GB/s HBM) — default prices are bit-identical to
the original single-target model.  The model is deterministic — the RL
reward is hardware-grounded without a GPU/TPU attached (DESIGN.md §2,
deviation 2).

Rewrite rules contribute pricing through registry hooks (DESIGN.md
§12) rather than edits here: matmul FLOPs are bucketed by each node's
*compute dtype* (a rule hook; default = the program's storage dtype,
exactly the old single-bucket behavior) and priced by the target's
per-dtype FLOP/s table, and each rule may adjust a matmul node's HBM
traffic (``rules.matmul_price`` — e.g. split-K's stream-occupancy term
and partial-sum bytes).  Hooks may refine the base model (the
occupancy term prices every skinny-M matmul, split or not — that is
the under-modeled physics the split_k action then buys back), but all
of them are exactly neutral on every pre-registry program: no task,
train program or benchmark rewrite has a skinny matmul or a rule
marker, so committed prices are unchanged to the bit
(regression-tested).
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core import hardware, rules
from repro.core.hardware import HardwareTarget
from repro.core.kernel_ir import KernelProgram, TensorSpec

# default-target (tpu_v5e) constants, kept as module aliases for code
# and docs that refer to the single-target model
PEAK_FLOPS = hardware.resolve(None).matmul_flops("bf16")
VPU_FLOPS = hardware.resolve(None).vector_flops
HBM_BW = hardware.resolve(None).hbm_bw
LAUNCH_S = hardware.resolve(None).launch_s


@dataclasses.dataclass(frozen=True)
class GroupCost:
    root: str
    mxu_flops: float
    vpu_flops: float
    hbm_bytes: float
    compute_s: float
    memory_s: float
    time_s: float
    bottleneck: str


@dataclasses.dataclass(frozen=True)
class ProgramCost:
    total_s: float
    groups: tuple[GroupCost, ...]
    target: str = hardware.DEFAULT_TARGET

    @property
    def bottleneck(self) -> str:
        worst = max(self.groups, key=lambda g: g.time_s)
        return f"{worst.root}:{worst.bottleneck}"


def group_cost(prog: KernelProgram, group: tuple[str, ...],
               shapes: dict[str, TensorSpec],
               target: HardwareTarget | str | None = None) -> GroupCost:
    tgt = hardware.resolve(target)
    nm = prog.node_map
    sched = prog.schedule_for(group)
    tiles = sched.blocks_dict
    in_specs = prog.input_specs
    internal = set(group)

    # matmul FLOPs bucketed by compute dtype: the bucket is the node's
    # rule-declared compute dtype when set (rules.compute_dtype_of),
    # else the program's storage dtype — the old single-bucket model
    prog_dtype = prog.inputs[0][1].dtype if prog.inputs else "bf16"
    mxu_by: dict[str, float] = {}

    def add_mxu(node, flops):
        dt = rules.compute_dtype_of(node) or prog_dtype
        mxu_by[dt] = mxu_by.get(dt, 0.0) + flops

    vpu = 0.0
    hbm_in = hbm_out = 0.0
    reorder_penalty = 0.0

    # bytes entering the group from HBM (external inputs + other groups'
    # intermediates), with tiling-induced re-reads for the anchor ops
    for name in group:
        n = nm[name]
        out = shapes[name]
        if n.op == "matmul":
            a, b = shapes_of(n.inputs, shapes, in_specs)
            M = int(np.prod(a.shape[:-1]))
            K, N = a.shape[-1], b.shape[-1]
            add_mxu(n, 2.0 * M * K * N)
            bm = tiles.get("bm", 128)
            bn = tiles.get("bn", 128)
            bk = tiles.get("bk", 128)
            node_hbm = 0.0
            if n.inputs[0] not in internal:
                node_hbm += a.bytes * max(1, N // max(bn, 1))
            if n.inputs[1] not in internal:
                node_hbm += b.bytes * max(1, M // max(bm, 1))
            # registry pricing hooks (neutral on classic programs):
            # stream-occupancy scaling, partial-sum traffic, reduces
            adj = rules.matmul_price(n, sched, out, M, N, K, tiles, tgt)
            hbm_in += node_hbm * adj.hbm_scale + adj.hbm_extra
            vpu += adj.vpu_extra
            order = sched.loop_order or ("m", "n", "k")
            if order[-1] != "k":
                reorder_penalty += 2.0 * M * N * 4 * max(1, K // bk)
        elif n.op == "grouped_matmul":
            a, b = shapes_of(n.inputs, shapes, in_specs)
            E, C, D = a.shape
            F = b.shape[-1]
            add_mxu(n, 2.0 * E * C * D * F)
            bc = tiles.get("bc", 128)
            bf = tiles.get("bf", 128)
            if n.inputs[0] not in internal:
                hbm_in += a.bytes * max(1, F // bf)
            if n.inputs[1] not in internal:
                hbm_in += b.bytes * max(1, C // bc)
        elif n.op in ("qk_scores", "av"):
            a, b = shapes_of(n.inputs, shapes, in_specs)
            if n.op == "qk_scores":
                B, Sq, H, hd = a.shape
                Sk = b.shape[1]
                M, K, N = Sq, hd, Sk
            else:
                B, H, Sq, Sk = a.shape
                hd = b.shape[-1]
                M, K, N = Sq, Sk, hd
            add_mxu(n, 2.0 * B * H * M * K * N)
            bm = tiles.get("bm", 128)
            bn = tiles.get("bn", 128)
            if n.inputs[0] not in internal:
                hbm_in += a.bytes * max(1, N // max(bn, 1))
            if n.inputs[1] not in internal:
                hbm_in += b.bytes * max(1, M // max(bm, 1))
        elif n.op == "attention":
            q, k = shapes_of(n.inputs[:2], shapes, in_specs)
            B, Sq, H, hd = q.shape
            Sk = k.shape[1]
            add_mxu(n, 4.0 * B * Sq * Sk * H * hd)
            vpu += 6.0 * B * Sq * Sk * H          # softmax chain
            bq = tiles.get("bq", 128)
            for inp in n.inputs[:1]:
                if inp not in internal:
                    hbm_in += shapes.get(inp, in_specs.get(inp)).bytes
            kv_bytes = sum(shapes.get(i, in_specs.get(i)).bytes
                           for i in n.inputs[1:3])
            hbm_in += kv_bytes * max(1, Sq // max(bq, 1))
        elif n.op in ("rwkv_chunk", "ssm_chunk"):
            x = shapes.get(n.inputs[0], in_specs.get(n.inputs[0]))
            T = x.shape[1]
            c = tiles.get("chunk", 64)
            feat = int(np.prod(x.shape[2:]))
            B = x.shape[0]
            # intra-chunk pairwise work + inter-chunk state matmuls
            vpu += 3.0 * B * T * c * feat
            add_mxu(n, 4.0 * B * T * feat * 64)
            for inp in n.inputs:
                if inp not in internal and (
                        inp in shapes or inp in in_specs):
                    hbm_in += shapes.get(inp, in_specs.get(inp)).bytes
        elif n.op == "softmax":
            vpu += 5.0 * out.elems
            hbm_in += _plain_input_bytes(n, internal, shapes, in_specs)
        elif n.op == "rmsnorm":
            vpu += 4.0 * out.elems
            hbm_in += _plain_input_bytes(n, internal, shapes, in_specs)
        elif n.op in ("row_max", "row_sum"):
            x = shapes.get(n.inputs[0], in_specs.get(n.inputs[0]))
            vpu += float(x.elems)
            hbm_in += _plain_input_bytes(n, internal, shapes, in_specs)
        else:  # elementwise
            vpu += 2.0 * out.elems
            hbm_in += _plain_input_bytes(n, internal, shapes, in_specs)

    # bytes leaving the group (consumed elsewhere or program outputs)
    consumers = _external_consumers(prog, group)
    for name in consumers:
        hbm_out += shapes[name].bytes

    mxu = sum(mxu_by.values())
    eff = tgt.mxu_efficiency(tiles) if mxu else 1.0
    # each compute-dtype bucket is priced at the target's per-dtype
    # peak (HardwareTarget.matmul_flops); with a single storage-dtype
    # bucket this reduces exactly to the old expression
    compute_s = sum(f / (tgt.matmul_flops(dt) * eff)
                    for dt, f in mxu_by.items()) \
        + vpu / tgt.vector_flops
    memory_s = (hbm_in + hbm_out + reorder_penalty) / tgt.hbm_bw
    if sched.pipeline_depth >= 2:
        time_s = max(compute_s, memory_s)
    else:
        time_s = compute_s + memory_s
    time_s += tgt.launch_s
    return GroupCost(prog.group_root(group), mxu, vpu,
                     hbm_in + hbm_out + reorder_penalty, compute_s,
                     memory_s, time_s,
                     "compute" if compute_s >= memory_s else "memory")


def shapes_of(names, shapes, in_specs):
    return [shapes.get(n, in_specs.get(n)) for n in names]


def _plain_input_bytes(n, internal, shapes, in_specs):
    total = 0.0
    for inp in n.inputs:
        if inp not in internal:
            spec = shapes.get(inp, in_specs.get(inp))
            if spec is not None:
                total += spec.bytes
    return total


def _external_consumers(prog: KernelProgram, group: tuple[str, ...]):
    internal = set(group)
    used_outside = set()
    for n in prog.nodes:
        if n.name in internal:
            continue
        for inp in n.inputs:
            if inp in internal:
                used_outside.add(inp)
    for o in prog.outputs:
        if o in internal:
            used_outside.add(o)
    return used_outside


def program_cost(prog: KernelProgram,
                 target: HardwareTarget | str | None = None
                 ) -> ProgramCost:
    tgt = hardware.resolve(target)
    shapes = prog.shapes()
    groups = tuple(group_cost(prog, g, shapes, tgt)
                   for g in prog.fusion_groups)
    return ProgramCost(sum(g.time_s for g in groups), groups, tgt.name)


def speedup(baseline: KernelProgram, optimized: KernelProgram,
            target: HardwareTarget | str | None = None) -> float:
    return program_cost(baseline, target).total_s / \
        max(program_cost(optimized, target).total_s, 1e-12)
