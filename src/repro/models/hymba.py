"""Hymba — hybrid blocks with PARALLEL attention + SSM heads.
[arXiv:2411.13676]

Each block runs a GQA attention path (sliding-window except 3 global
layers) and a Mamba-style SSM path on the same normed input; the two
normalized outputs are averaged (the paper's mean-fusion of parallel
heads).  The SSM path uses SSD-style scalar-per-head decay (TPU/MXU-native
adaptation of selective scan — DESIGN.md §2) with P=128 channels/head.

Decode is unrolled per layer (not scanned) because the global-attention
layers carry a full-length KV cache while SWA layers carry a ring buffer
of window size — heterogeneous cache shapes (see DESIGN.md; this is the
memory feature that makes long_500k decode feasible).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.kernels import ops
from repro.models import layers, transformer
from repro.models.layers import (
    apply_rope, linear, normal_init, ones_init, rms_norm, zeros_init,
)

SSM_P = 128   # channels per SSM head
CONV_K = 4    # depthwise causal conv width


def _ssm_dims(cfg: ModelConfig) -> tuple[int, int]:
    d_in = cfg.ssm_expand * cfg.d_model
    return d_in, d_in // SSM_P   # (d_inner, n_ssm_heads)


def _a_init():
    def init(key, shape, dtype):
        return -jnp.exp(jax.random.uniform(
            key, shape, jnp.float32, -2.0, 1.0)).astype(dtype)
    return init


def ssm_tree(cfg: ModelConfig, make, L: int):
    D, N = cfg.d_model, cfg.ssm_state
    d_in, Hs = _ssm_dims(cfg)
    w = normal_init(0.02)
    return {
        "s_in": make("s_in", (L, D, 2 * d_in), ("layers", "embed", "heads"),
                     w),
        "s_conv": make("s_conv", (L, CONV_K, d_in),
                       ("layers", None, "heads"), normal_init(0.1)),
        "s_dt": make("s_dt", (L, D, Hs), ("layers", "embed", None), w),
        "s_dt_bias": make("s_dt_bias", (L, Hs), ("layers", None),
                          zeros_init()),
        "s_B": make("s_B", (L, D, N), ("layers", "embed", None), w),
        "s_C": make("s_C", (L, D, N), ("layers", "embed", None), w),
        "s_A": make("s_A", (L, Hs), ("layers", None), _a_init()),
        "s_D": make("s_D", (L, Hs), ("layers", None), ones_init()),
        "s_norm": make("s_norm", (L, d_in), ("layers", "heads"),
                       ones_init()),
        "s_out": make("s_out", (L, d_in, D), ("layers", "heads", "embed"),
                      normal_init(layers.depth_scale(0.02, L))),
        "attn_out_norm": make("attn_out_norm", (L, cfg.d_model),
                              ("layers", "embed"), ones_init()),
        "ssm_out_norm": make("ssm_out_norm", (L, cfg.d_model),
                             ("layers", "embed"), ones_init()),
    }


def param_tree(cfg: ModelConfig, make):
    t = transformer.param_tree(cfg, make)
    t["blocks"].update(ssm_tree(cfg, make, cfg.n_layers))
    return t


# ---------------------------------------------------------------------------
# SSM path
# ---------------------------------------------------------------------------

def _causal_conv(x: jax.Array, kernel: jax.Array,
                 state: jax.Array | None = None):
    """Depthwise causal conv over time.  x: (B,T,C), kernel: (K,C).
    state: (B,K-1,C) trailing context (decode).  Returns (y, new_state)."""
    B, T, C = x.shape
    K = kernel.shape[0]
    pad = jnp.zeros((B, K - 1, C), x.dtype) if state is None \
        else state.astype(x.dtype)
    xp = jnp.concatenate([pad, x], axis=1)                # (B,T+K-1,C)
    y = sum(xp[:, i:i + T] * kernel[i].astype(x.dtype) for i in range(K))
    return y, xp[:, -(K - 1):]


def ssm_path(cfg: ModelConfig, p: dict, h: jax.Array, *,
             conv_state=None, ssm_state=None, rules=None):
    """h: (B,T,D) normed -> (out (B,T,D), (conv_state, ssm_state))."""
    B, T, D = h.shape
    N = cfg.ssm_state
    d_in, Hs = _ssm_dims(cfg)
    xz = linear(h, p["s_in"])                             # (B,T,2*d_in)
    x, z = jnp.split(xz, 2, axis=-1)
    x, new_conv = _causal_conv(x, p["s_conv"], conv_state)
    x = jax.nn.silu(x)
    dt = jax.nn.softplus(linear(h, p["s_dt"])
                         + p["s_dt_bias"].astype(h.dtype))  # (B,T,Hs)
    B_ = linear(h, p["s_B"])                              # (B,T,N)
    C_ = linear(h, p["s_C"])
    xh = x.reshape(B, T, Hs, SSM_P)
    if rules is not None:
        xh = rules.constrain(xh, ("batch", None, "heads", None))
    y, new_state = ops.ssm_scan(xh, dt, p["s_A"], B_, C_, ssm_state)
    y = y + p["s_D"].astype(y.dtype)[None, None, :, None] * xh
    y = y.reshape(B, T, d_in)
    y = rms_norm(y, p["s_norm"], cfg.norm_eps) * jax.nn.silu(z)
    return linear(y, p["s_out"]), (new_conv, new_state)


# ---------------------------------------------------------------------------
# forward (scan over layers; both paths share the pre-norm input)
# ---------------------------------------------------------------------------

def forward(cfg: ModelConfig, params: dict, batch: dict, *, rules=None,
            remat: bool = True, collect_cache: bool = False):
    tokens = batch["tokens"]
    cdt = jnp.dtype(cfg.compute_dtype)
    x = params["embed"].astype(cdt)[tokens]
    if rules is not None:
        x = rules.constrain(x, ("batch", None, None))

    def block(x, scanned):
        p, idx = scanned
        B, S, D = x.shape
        positions = jnp.arange(S)
        window = transformer._window_for_layer(cfg, idx)
        attn_out = transformer.attn_block(
            cfg, p, x, positions=positions, window=window, rules=rules)
        h = ops.rmsnorm(x, p["attn_norm"], eps=cfg.norm_eps)
        ssm_out, _ = ssm_path(cfg, p, h, rules=rules)
        fused = 0.5 * (
            rms_norm(attn_out, p["attn_out_norm"], cfg.norm_eps)
            + rms_norm(ssm_out, p["ssm_out_norm"], cfg.norm_eps))
        x = x + fused
        delta, aux = transformer.mlp_block(cfg, p, x, rules)
        x = x + delta
        if rules is not None:
            x = rules.constrain(x, ("batch", None, None))
        return x, aux

    if remat:
        block = jax.checkpoint(
            block, policy=jax.checkpoint_policies.nothing_saveable)
    idxs = jnp.arange(cfg.n_layers)
    x, aux = jax.lax.scan(block, x, (params["blocks"], idxs))
    x = ops.rmsnorm(x, params["final_norm"], eps=cfg.norm_eps)
    logits = transformer.unembed(cfg, params, x, rules)
    return logits, jnp.mean(aux)


# ---------------------------------------------------------------------------
# decode: heterogeneous caches (ring buffers for SWA, full for global)
# ---------------------------------------------------------------------------

def cache_tree(cfg: ModelConfig, make, batch: int, max_len: int):
    KV, hd = cfg.n_kv_heads, cfg.head_dim
    d_in, Hs = _ssm_dims(cfg)
    W = min(cfg.swa_window, max_len) if cfg.swa_window else max_len
    t = {}
    for i in range(cfg.n_layers):
        is_global = i in cfg.global_layers
        S = max_len if is_global else W
        t[f"k{i}"] = make(f"cache_k{i}", (batch, S, KV, hd),
                          ("batch", "kv_seq" if is_global else None,
                           "kv_heads", None), zeros_init())
        t[f"v{i}"] = make(f"cache_v{i}", (batch, S, KV, hd),
                          ("batch", "kv_seq" if is_global else None,
                           "kv_heads", None), zeros_init())
        t[f"conv{i}"] = make(f"cache_conv{i}", (batch, CONV_K - 1, d_in),
                             ("batch", None, "heads"), zeros_init())
        t[f"ssm{i}"] = make(f"cache_ssm{i}",
                            (batch, Hs, SSM_P, cfg.ssm_state),
                            ("batch", "heads", None, None), zeros_init())
    return t


def decode_step(cfg: ModelConfig, params: dict, cache: dict,
                tokens: jax.Array, pos: jax.Array, *, rules=None):
    cdt = jnp.dtype(cfg.compute_dtype)
    B = tokens.shape[0]
    H, KV, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    W = cfg.swa_window
    x = params["embed"].astype(cdt)[tokens]
    positions = jnp.full((1,), pos)
    new_cache = {}
    blocks = params["blocks"]

    for i in range(cfg.n_layers):
        p = jax.tree.map(lambda a, i=i: a[i], blocks)
        is_global = i in cfg.global_layers
        h = ops.rmsnorm(x, p["attn_norm"], eps=cfg.norm_eps)
        q = linear(h, p["wq"], p.get("bq")).reshape(B, 1, H, hd)
        k = linear(h, p["wk"], p.get("bk")).reshape(B, 1, KV, hd)
        v = linear(h, p["wv"], p.get("bv")).reshape(B, 1, KV, hd)
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
        ck, cv = cache[f"k{i}"], cache[f"v{i}"]
        slot = pos if is_global else (pos % W if W else pos)
        ck = jax.lax.dynamic_update_slice(ck, k.astype(ck.dtype),
                                          (0, slot, 0, 0))
        cv = jax.lax.dynamic_update_slice(cv, v.astype(cv.dtype),
                                          (0, slot, 0, 0))
        if is_global:
            o = ops.decode_attention(q, ck, cv, pos)
        else:
            # ring buffer: valid slots are j <= pos (early) or all (wrapped)
            S = ck.shape[1]
            valid = (jnp.arange(S) <= pos) | (pos >= S)
            scores = layers._gqa_scores(q * hd ** -0.5, ck)
            scores = jnp.where(valid[None, None, None, None, :],
                               scores, -1e30)
            probs = jax.nn.softmax(scores, -1).astype(cv.dtype)
            o = layers._gqa_out(probs, cv)
        attn_out = linear(o.reshape(B, 1, H * hd), p["wo"])
        ssm_out, (conv_s, ssm_s) = ssm_path(
            cfg, p, h, conv_state=cache[f"conv{i}"],
            ssm_state=cache[f"ssm{i}"], rules=rules)
        fused = 0.5 * (
            rms_norm(attn_out, p["attn_out_norm"], cfg.norm_eps)
            + rms_norm(ssm_out, p["ssm_out_norm"], cfg.norm_eps))
        x = x + fused
        delta, _ = transformer.mlp_block(cfg, p, x, rules)
        x = x + delta
        new_cache[f"k{i}"], new_cache[f"v{i}"] = ck, cv
        new_cache[f"conv{i}"] = conv_s.astype(cache[f"conv{i}"].dtype)
        new_cache[f"ssm{i}"] = ssm_s.astype(cache[f"ssm{i}"].dtype)

    x = ops.rmsnorm(x, params["final_norm"], eps=cfg.norm_eps)
    logits = transformer.unembed(cfg, params, x, rules)
    return logits, new_cache
