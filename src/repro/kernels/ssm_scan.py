"""SSD-style SSM chunked scan kernel (Pallas TPU).

Mamba-2-style scalar-per-head decay makes the chunked form pure matmuls
(1-semiseparable structure) — the MXU-native adaptation of selective scan
(DESIGN.md §2).  Grid (B, Hs, n_chunks), per-(b,h) state (P x N, f32) in
VMEM scratch across the sequential chunk axis.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.compat import CompilerParams
from repro.kernels.schedule import KernelSchedule, default_schedule


def _kernel(x_ref, dt_ref, a_ref, b_ref, c_ref, h0_ref, o_ref, hout_ref,
            Hs, *, nc: int, c: int):
    ti = pl.program_id(2)

    @pl.when(ti == 0)
    def _init():
        Hs[...] = h0_ref[0, 0].astype(jnp.float32)

    xc = x_ref[0, 0].astype(jnp.float32)          # (c, P)
    dtc = dt_ref[0, 0].astype(jnp.float32)        # (c,)
    a = a_ref[0, 0].astype(jnp.float32)           # scalar
    bc = b_ref[0].astype(jnp.float32)             # (c, N)
    cc = c_ref[0].astype(jnp.float32)             # (c, N)

    la = a * dtc                                  # (c,) <= 0
    ccum = jnp.cumsum(la)                         # (c,)

    h = Hs[...]                                   # (P, N)
    y_inter = jnp.exp(ccum)[:, None] * jnp.dot(
        cc, h.T, preferred_element_type=jnp.float32)           # (c, P)

    diff = ccum[:, None] - ccum[None, :]                       # (c, c)
    tri = jax.lax.broadcasted_iota(jnp.int32, (c, c), 0) >= \
        jax.lax.broadcasted_iota(jnp.int32, (c, c), 1)
    L = jnp.where(tri, jnp.exp(jnp.minimum(diff, 0.0)), 0.0)
    S = jnp.dot(cc, bc.T, preferred_element_type=jnp.float32)  # (c, c)
    G = L * S
    y_intra = jnp.dot(G, dtc[:, None] * xc,
                      preferred_element_type=jnp.float32)      # (c, P)
    o_ref[0, 0] = (y_inter + y_intra).astype(o_ref.dtype)

    rem = ccum[-1] - ccum                                      # <= 0
    xd = (dtc * jnp.exp(rem))[:, None] * xc                    # (c, P)
    upd = jnp.dot(xd.T, bc, preferred_element_type=jnp.float32)  # (P, N)
    Hs[...] = jnp.exp(ccum[-1]) * h + upd

    @pl.when(ti == nc - 1)
    def _fin():
        hout_ref[0, 0] = Hs[...]


@functools.partial(jax.jit, static_argnames=("schedule", "interpret"))
def ssm_scan(x, dt, A, B_, C, state=None, *,
             schedule: KernelSchedule | None = None,
             interpret: bool = False):
    """x: (B,T,H,P); dt: (B,T,H); A: (H,); B_,C: (B,T,N);
    state: (B,H,P,N).  Returns (y (B,T,H,P), state f32)."""
    s = schedule or default_schedule("ssm_scan")
    Bb, T, H, P = x.shape
    N = B_.shape[-1]
    c = min(s.block("chunk", 64), T)
    assert T % c == 0
    nc = T // c
    if state is None:
        state = jnp.zeros((Bb, H, P, N), jnp.float32)
    xt = x.transpose(0, 2, 1, 3)                  # (B,H,T,P)
    dtt = dt.transpose(0, 2, 1)                   # (B,H,T)
    a2 = A.reshape(H, 1)

    y, h_out = pl.pallas_call(
        functools.partial(_kernel, nc=nc, c=c),
        grid=(Bb, H, nc),
        in_specs=[
            pl.BlockSpec((1, 1, c, P), lambda b, h, t: (b, h, t, 0)),
            pl.BlockSpec((1, 1, c), lambda b, h, t: (b, h, t)),
            pl.BlockSpec((1, 1), lambda b, h, t: (h, 0)),
            pl.BlockSpec((1, c, N), lambda b, h, t: (b, t, 0)),
            pl.BlockSpec((1, c, N), lambda b, h, t: (b, t, 0)),
            pl.BlockSpec((1, 1, P, N), lambda b, h, t: (b, h, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, c, P), lambda b, h, t: (b, h, t, 0)),
            pl.BlockSpec((1, 1, P, N), lambda b, h, t: (b, h, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((Bb, H, T, P), x.dtype),
            jax.ShapeDtypeStruct((Bb, H, P, N), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((P, N), jnp.float32)],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(xt, dtt, a2, B_, C, state)
    return y.transpose(0, 2, 1, 3), h_out
